"""§Perf hillclimb, cell 3: the LP solver iteration (the paper's technique).

Unlike the LM cells (analyzed via compiled rooflines), the solver runs for
real on this host, so each hypothesis is validated by measured wall-clock
per-iteration time at I=100k × J=1k (and by checking the converged dual is
unchanged).  Iterations:

  it0  baseline: paper-faithful pipeline (bucketed slabs, 40-sweep bisection
       projection, segment-sum gradient), jit-compiled.
  it1  hypothesis: the projection's 40 masked clip+sum sweeps dominate the
       per-iteration time (napkin: 40 sweeps x nnz ops vs ~6 sweeps for
       everything else). change: bisection 40 -> 20 sweeps (τ precision
       2^-20·range ≈ f32 noise here).  expect ~linear cut of projection time.
  it2  hypothesis: a safeguarded-Newton threshold search needs ~1/3 the
       sweeps of pure bisection on piecewise-linear f. change: kind
       "boxcut_newton" (12 sweeps, bracket-safeguarded).
  it3  hypothesis: two passes over a_vals (u = −(Aᵀλ+c)/γ, then gvals=a·x)
       dominate memory traffic after it2; fusing them is what the Pallas
       dual_grad kernel does on TPU — on CPU XLA already fuses, so expect
       ~no change (refutation expected; documents why the kernel targets
       TPU VMEM, not CPU cache).  change: use_pallas=False vs the fused
       jnp expression ordering.
  it4  hypothesis: even the sorted segment-sum of it3 is a serialized
       scatter on CPU/GPU backends; the destination-major AxPlan companion
       layout (paper §6 "constraint-aligned sparse layouts") replaces it
       with dense masked gather row-sums — fixed shapes, no write
       contention.  change: ax_mode="aligned" (keeps it1's bisect20).
  it5  same aligned reduction routed through the Pallas gather-reduce
       kernel (kernels/ax_reduce.py; interpret-mode on CPU — the row
       documents TPU-kernel correctness + CPU cost, as the kernels suite
       does for dual_grad).

Each row reports: us/iter, speedup vs baseline, and |Δdual| of the converged
objective vs baseline (dual_drift_rel must be ~0 for accepted changes —
the it4/it5 guards in run.py's emitted JSON).

`run_tolerance` additionally carries a formulation-subsystem row
(`tol_multi_budget_aligned`): the multi_budget spec compiled through
repro.formulations and solved to the same tolerances — the new subsystem
stays on the perf trajectory from the day it lands.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (MatchingObjective, Maximizer, SolveConfig,
                        StoppingCriteria, precondition)
from .lp_common import bench_instance


def _time_solve(lp, kind: str, proj_iters: int, iterations: int = 60,
                repeats: int = 3, sorted_scatter: bool = False,
                ax_mode=None, use_pallas: bool = False):
    cfg = SolveConfig(iterations=iterations, gamma=0.01, max_step=1e-3,
                      initial_step=1e-5)
    obj = MatchingObjective(lp, proj_kind=kind, proj_iters=proj_iters,
                            sorted_scatter=sorted_scatter, ax_mode=ax_mode,
                            use_pallas=use_pallas)
    mx = Maximizer(cfg)
    res = mx.maximize(obj)
    jax.block_until_ready(res.lam)
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = mx.maximize(obj)
        jax.block_until_ready(res.lam)
        best = min(best, (time.perf_counter() - t0) / iterations)
    return best, float(res.stats.dual_obj[-1])


def run(quick: bool = False):
    I = 50_000 if quick else 100_000
    # CPU-feasibility rescale: the scatter rows cost tens of seconds per
    # iteration at I=100k on this host, so the suite measures a short fixed
    # iteration count (per-iteration time is iteration-count-independent:
    # fixed shapes, no data-dependent control flow) and one timed repeat —
    # compile is excluded by the Maximizer's jit cache, and all rows use the
    # same count so the dual comparisons stay apples-to-apples.
    iters = 6 if quick else 12
    reps = 1
    spec, lp_host = bench_instance(I)
    lp = jax.tree.map(jnp.asarray, lp_host)
    lp, _ = precondition(lp, row_norm=True)

    rows = []
    t0, d0 = _time_solve(lp, "boxcut", 40, iterations=iters, repeats=reps)
    rows.append({"name": "perf_lp/it0_baseline_bisect40",
                 "us_per_call": t0 * 1e6,
                 "derived": {"dual": d0, "speedup": 1.0}})
    t1, d1 = _time_solve(lp, "boxcut", 20, iterations=iters, repeats=reps)
    rows.append({"name": "perf_lp/it1_bisect20",
                 "us_per_call": t1 * 1e6,
                 "derived": {"dual": d1, "speedup": t0 / t1,
                             "dual_drift_rel": abs(d1 - d0) / abs(d0)}})
    t2, d2 = _time_solve(lp, "boxcut_newton", 12, iterations=iters,
                         repeats=reps)
    rows.append({"name": "perf_lp/it2_newton12",
                 "us_per_call": t2 * 1e6,
                 "derived": {"dual": d2, "speedup": t0 / t2,
                             "dual_drift_rel": abs(d2 - d0) / abs(d0)}})
    # it3: sorted-destination segmented sum replaces the random scatter-add
    # (keeps it1's accepted bisect20)
    t3, d3 = _time_solve(lp, "boxcut", 20, sorted_scatter=True,
                         iterations=iters, repeats=reps)
    rows.append({"name": "perf_lp/it3_bisect20_sorted_scatter",
                 "us_per_call": t3 * 1e6,
                 "derived": {"dual": d3, "speedup": t0 / t3,
                             "dual_drift_rel": abs(d3 - d0) / abs(d0)}})
    # it4: scatter-free constraint-aligned gather reduction (AxPlan)
    t4, d4 = _time_solve(lp, "boxcut", 20, ax_mode="aligned",
                         iterations=iters, repeats=reps)
    rows.append({"name": "perf_lp/it4_aligned_ax",
                 "us_per_call": t4 * 1e6,
                 "derived": {"dual": d4, "speedup": t0 / t4,
                             "speedup_vs_it3": t3 / t4,
                             "dual_drift_rel": abs(d4 - d0) / abs(d0)}})
    # it5: same reduction through the Pallas gather-reduce kernel
    t5, d5 = _time_solve(lp, "boxcut", 20, ax_mode="aligned",
                         use_pallas=True, iterations=iters, repeats=reps)
    rows.append({"name": "perf_lp/it5_aligned_ax_pallas",
                 "us_per_call": t5 * 1e6,
                 "derived": {"dual": d5, "speedup": t0 / t5,
                             "speedup_vs_it3": t3 / t5,
                             "dual_drift_rel": abs(d5 - d0) / abs(d0)}})
    return rows


def run_tolerance(quick: bool = False):
    """Wall-clock-to-tolerance — the paper's actual headline metric.

    The ≥10x claim is made "under matched stopping criteria": both Ax
    layouts run under ONE StoppingCriteria (same tolerances, same check
    cadence) and each row reports the seconds and iterations it took to get
    there, plus the stop reason.  The scatter row is wall-clock-capped: on
    this CPU host it may exhaust the budget before reaching tolerance, and
    `stop_reason="max_seconds"` records that honestly instead of a
    fixed-iteration timing pretending both did equal work.  Sizes are scaled
    down from the fixed-iteration rows so the converging row finishes in
    minutes on one core."""
    I = 2_000 if quick else 10_000
    spec, lp_host = bench_instance(I)
    lp = jax.tree.map(jnp.asarray, lp_host)
    lp, _ = precondition(lp, row_norm=True)
    cfg = SolveConfig(iterations=4000, gamma=0.01, max_step=1e-1,
                      initial_step=1e-5)
    crit = StoppingCriteria(tol_rel_dual=1e-6, tol_infeas_rel=1e-4,
                            check_every=25,
                            max_seconds=60.0 if quick else 300.0)
    rows, secs = [], {}
    for tag, ax_mode in [("scatter", "scatter"), ("aligned", "aligned")]:
        obj = MatchingObjective(lp, proj_kind="boxcut", proj_iters=20,
                                ax_mode=ax_mode)
        mx = Maximizer(cfg)
        # warm-up: compile the check_every-length chunk runner (same engine
        # cache key as the timed run) so the row times iterations to
        # tolerance, not each layout's XLA compile
        warm = mx.maximize(obj, criteria=StoppingCriteria(
            max_iterations=crit.check_every))
        jax.block_until_ready(warm.lam)
        t0 = time.perf_counter()
        res = mx.maximize(obj, criteria=crit)
        jax.block_until_ready(res.lam)
        dt = time.perf_counter() - t0
        secs[tag] = (dt, res)
        rows.append({
            "name": f"perf_lp/tol_{tag}",
            "us_per_call": dt / max(res.iterations_run, 1) * 1e6,
            "derived": {
                "seconds_to_stop": dt,
                "iterations_run": res.iterations_run,
                "stop_reason": res.stop_reason.value,
                "converged": res.converged,
                "dual": float(res.stats.dual_obj[-1]),
                "infeas": float(res.stats.infeas[-1]),
                "checks": len(res.diagnostics),
            }})
    dt_sc, res_sc = secs["scatter"]
    dt_al, res_al = secs["aligned"]
    rows[-1]["derived"]["wallclock_speedup_vs_scatter"] = dt_sc / dt_al
    if res_sc.converged and res_al.converged:
        rows[-1]["derived"]["dual_drift_rel"] = (
            abs(float(res_al.stats.dual_obj[-1])
                - float(res_sc.stats.dual_obj[-1]))
            / abs(float(res_sc.stats.dual_obj[-1])))

    # the formulation-subsystem row: multi_budget (capacity + global count
    # + global value caps, DESIGN.md §5) compiled onto the same engine with
    # the aligned layout — keeps the new subsystem on the perf trajectory.
    # Stopping: the dual-stability rule at the same tolerance/cadence; the
    # infeasibility rule is dropped for this row because its binding
    # coupling rows carry a γ-regularization residual floor (reported in
    # `infeas`) that no fixed tol_infeas_rel can undercut across instances.
    from repro import formulations
    crit_mb = StoppingCriteria(tol_rel_dual=crit.tol_rel_dual,
                               check_every=crit.check_every,
                               max_seconds=crit.max_seconds)
    obj = formulations.make_objective("multi_budget", lp_host,
                                      ax_mode="aligned", row_norm=True)
    mx = Maximizer(cfg)
    warm = mx.maximize(obj, criteria=StoppingCriteria(
        max_iterations=crit.check_every))
    jax.block_until_ready(warm.lam)
    t0 = time.perf_counter()
    res = mx.maximize(obj, criteria=crit_mb)
    jax.block_until_ready(res.lam)
    dt = time.perf_counter() - t0
    rows.append({
        "name": "perf_lp/tol_multi_budget_aligned",
        "us_per_call": dt / max(res.iterations_run, 1) * 1e6,
        "derived": {
            "seconds_to_stop": dt,
            "iterations_run": res.iterations_run,
            "stop_reason": res.stop_reason.value,
            "converged": res.converged,
            "dual": float(res.stats.dual_obj[-1]),
            "infeas": float(res.stats.infeas[-1]),
            "checks": len(res.diagnostics),
            "dual_rows": int(obj.dual_shape[0]),
        }})
    return rows
