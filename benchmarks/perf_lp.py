"""§Perf hillclimb, cell 3: the LP solver iteration (the paper's technique).

Unlike the LM cells (analyzed via compiled rooflines), the solver runs for
real on this host, so each hypothesis is validated by measured wall-clock
per-iteration time at I=100k × J=1k (and by checking the converged dual is
unchanged).  Iterations:

  it0  baseline: paper-faithful pipeline (bucketed slabs, 40-sweep bisection
       projection, segment-sum gradient), jit-compiled.
  it1  hypothesis: the projection's 40 masked clip+sum sweeps dominate the
       per-iteration time (napkin: 40 sweeps x nnz ops vs ~6 sweeps for
       everything else). change: bisection 40 -> 20 sweeps (τ precision
       2^-20·range ≈ f32 noise here).  expect ~linear cut of projection time.
  it2  hypothesis: a safeguarded-Newton threshold search needs ~1/3 the
       sweeps of pure bisection on piecewise-linear f. change: kind
       "boxcut_newton" (12 sweeps, bracket-safeguarded).
  it3  hypothesis: two passes over a_vals (u = −(Aᵀλ+c)/γ, then gvals=a·x)
       dominate memory traffic after it2; fusing them is what the Pallas
       dual_grad kernel does on TPU — on CPU XLA already fuses, so expect
       ~no change (refutation expected; documents why the kernel targets
       TPU VMEM, not CPU cache).  change: use_pallas=False vs the fused
       jnp expression ordering.
  it4  hypothesis: even the sorted segment-sum of it3 is a serialized
       scatter on CPU/GPU backends; the destination-major AxPlan companion
       layout (paper §6 "constraint-aligned sparse layouts") replaces it
       with dense masked gather row-sums — fixed shapes, no write
       contention.  change: ax_mode="aligned_gvals" (keeps it1's bisect20;
       the gvals-consuming aligned lowering, pre-value-carrying).
  it5  same aligned reduction routed through the Pallas gather-reduce
       kernel (kernels/ax_reduce.py; interpret-mode on CPU — the row
       documents TPU-kernel correctness + CPU cost, as the kernels suite
       does for dual_grad).
  it6  hypothesis: it4 still pays HBM round-trips of the (E, m) per-edge
       gradient tensor (gvals write, concat copy, gather read) to multiply
       by weights that are *static*; packing a destination-major weight
       copy a_dm into the plan makes the reduction x-only — the only
       dynamic per-edge array is the (E,) x vector.
       change: ax_mode="aligned" (the value-carrying x-carry path).
  it7  the x-carry reduction through the Pallas kernels: gvals-free fused
       dual_x + ax_reduce_x (interpret-mode on CPU, as it5).

Each row reports: us/iter, speedup vs baseline, and |Δdual| of the converged
objective vs baseline (dual_drift_rel must be ~0 for accepted changes —
the it4..it7 guards in run.py's emitted JSON; it6/it7 additionally report
drift vs the it4 gvals-aligned lowering).

`run_bytes` is the analytic companion (launch/hlo_cost.py over the
compiled calculate): total / dynamic / edge-space bytes per iteration for
the scatter, gvals-aligned, and x-carry lowerings, plus the
(E, m)-tensor census — the "no gvals materialization" acceptance check
and the ≥2x dynamic edge-traffic claim, measured on a multi-family
(m=4) instance where the per-edge gradient tensor is genuinely wider
than x (at m=1 XLA already collapses the three logical round-trips into
one E-sized materialization, and the two layouts tie).

`run_tolerance` additionally carries an x-carry row (`tol_xcarry`, same
matched stopping criteria; its dual_drift_rel vs the gvals-aligned row is
the CI convergence gate) and a formulation-subsystem row
(`tol_multi_budget_aligned`): the multi_budget spec compiled through
repro.formulations and solved to the same tolerances — the new subsystem
stays on the perf trajectory from the day it lands.  It also races the
registered update rules (DESIGN.md §10): agd vs pdhg vs bb on every
registered formulation under one shared StoppingCriteria (dual stability
AND feasibility), rows `tol_agd`/`tol_pdhg`/`tol_bb` (matching) and
`tol_<rule>_<formulation>`, each reporting iterations- and
wall-clock-to-tolerance plus dual_drift_rel_vs_agd; `tol_rules_summary`
aggregates pdhg's per-formulation iteration speedups and the >= 2x count
the CI smoke gates on.

`run_serve` measures the primal serving subsystem (DESIGN.md §8) on a
solved instance: streaming-extraction throughput in sources/sec (compile
excluded via a warm-up pass) and microbatch query latency / sources-per-
second through the λ-resident AllocationServer, plus the certificate the
serve path is gated on (gap_rel, feasible).

`run_load` is the served-traffic row (DESIGN.md §12): a closed-loop load
test through the traffic-hardened ServerFrontend.  Phase 1 measures
single-client sustained qps (the coalescing layer's per-request round
trip); phase 2 drives 4 concurrent clients at the same deadline while a
`warm_resolve` lands mid-run, then drains.  The row reports sustained
qps at concurrency (must reach >= 2x the single-client rate — batches
coalesce across clients), p50/p99 latency of admitted queries (p99
bounded by the deadline, by classification), shed/timeout rates, and
that every request was classified with zero ERRORs — the function
raises on any unclassified failure rather than record a dishonest row.
"""
from __future__ import annotations

import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (MatchingObjective, Maximizer, SolveConfig,
                        StoppingCriteria, precondition)
from .lp_common import bench_instance


def _stamp_resources(rows):
    """Stamp process-level resource watermarks onto every emitted row.

    `peak_rss_bytes` is the host VmHWM (process lifetime — an upper bound
    on what the suite itself needed), `peak_hbm_bytes` the accelerator
    allocator's peak (None on CPU backends, recorded honestly rather than
    zero).  Rows become comparable across hosts/backends in
    bench_history.jsonl without a per-suite sampler thread.
    """
    from repro.obs.memory import device_memory_stats, host_peak_rss_bytes
    dev = device_memory_stats()
    marks = {"peak_rss_bytes": host_peak_rss_bytes(),
             "peak_hbm_bytes": (dev.get("peak_bytes_in_use")
                                if dev else None)}
    for r in rows:
        r.setdefault("derived", {}).update(marks)
    return rows


def _time_solve(lp, kind: str, proj_iters: int, iterations: int = 60,
                repeats: int = 3, sorted_scatter: bool = False,
                ax_mode=None, use_pallas: bool = False):
    cfg = SolveConfig(iterations=iterations, gamma=0.01, max_step=1e-3,
                      initial_step=1e-5)
    obj = MatchingObjective(lp, proj_kind=kind, proj_iters=proj_iters,
                            sorted_scatter=sorted_scatter, ax_mode=ax_mode,
                            use_pallas=use_pallas)
    mx = Maximizer(cfg)
    res = mx.maximize(obj)
    jax.block_until_ready(res.lam)
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = mx.maximize(obj)
        jax.block_until_ready(res.lam)
        best = min(best, (time.perf_counter() - t0) / iterations)
    return best, float(res.stats.dual_obj[-1])


def run(quick: bool = False):
    I = 50_000 if quick else 100_000
    # CPU-feasibility rescale: the scatter rows cost tens of seconds per
    # iteration at I=100k on this host, so the suite measures a short fixed
    # iteration count (per-iteration time is iteration-count-independent:
    # fixed shapes, no data-dependent control flow) and one timed repeat —
    # compile is excluded by the Maximizer's jit cache, and all rows use the
    # same count so the dual comparisons stay apples-to-apples.
    iters = 6 if quick else 12
    reps = 1
    spec, lp_host = bench_instance(I)
    lp = jax.tree.map(jnp.asarray, lp_host)
    lp, _ = precondition(lp, row_norm=True)

    rows = []
    t0, d0 = _time_solve(lp, "boxcut", 40, iterations=iters, repeats=reps)
    rows.append({"name": "perf_lp/it0_baseline_bisect40",
                 "us_per_call": t0 * 1e6,
                 "derived": {"dual": d0, "speedup": 1.0}})
    t1, d1 = _time_solve(lp, "boxcut", 20, iterations=iters, repeats=reps)
    rows.append({"name": "perf_lp/it1_bisect20",
                 "us_per_call": t1 * 1e6,
                 "derived": {"dual": d1, "speedup": t0 / t1,
                             "dual_drift_rel": abs(d1 - d0) / abs(d0)}})
    t2, d2 = _time_solve(lp, "boxcut_newton", 12, iterations=iters,
                         repeats=reps)
    rows.append({"name": "perf_lp/it2_newton12",
                 "us_per_call": t2 * 1e6,
                 "derived": {"dual": d2, "speedup": t0 / t2,
                             "dual_drift_rel": abs(d2 - d0) / abs(d0)}})
    # it3: sorted-destination segmented sum replaces the random scatter-add
    # (keeps it1's accepted bisect20)
    t3, d3 = _time_solve(lp, "boxcut", 20, sorted_scatter=True,
                         iterations=iters, repeats=reps)
    rows.append({"name": "perf_lp/it3_bisect20_sorted_scatter",
                 "us_per_call": t3 * 1e6,
                 "derived": {"dual": d3, "speedup": t0 / t3,
                             "dual_drift_rel": abs(d3 - d0) / abs(d0)}})
    # it4: scatter-free constraint-aligned gather reduction (AxPlan) over a
    # materialized (E, m) gvals tensor — the pre-value-carrying lowering
    t4, d4 = _time_solve(lp, "boxcut", 20, ax_mode="aligned_gvals",
                         iterations=iters, repeats=reps)
    rows.append({"name": "perf_lp/it4_aligned_ax",
                 "us_per_call": t4 * 1e6,
                 "derived": {"dual": d4, "speedup": t0 / t4,
                             "speedup_vs_it3": t3 / t4,
                             "dual_drift_rel": abs(d4 - d0) / abs(d0)}})
    # it5: same reduction through the Pallas gather-reduce kernel
    t5, d5 = _time_solve(lp, "boxcut", 20, ax_mode="aligned_gvals",
                         use_pallas=True, iterations=iters, repeats=reps)
    rows.append({"name": "perf_lp/it5_aligned_ax_pallas",
                 "us_per_call": t5 * 1e6,
                 "derived": {"dual": d5, "speedup": t0 / t5,
                             "speedup_vs_it3": t3 / t5,
                             "dual_drift_rel": abs(d5 - d0) / abs(d0)}})
    # it6: value-carrying x-only reduction (a_dm packed into the plan,
    # gvals never materialized)
    t6, d6 = _time_solve(lp, "boxcut", 20, ax_mode="aligned",
                         iterations=iters, repeats=reps)
    rows.append({"name": "perf_lp/it6_xcarry",
                 "us_per_call": t6 * 1e6,
                 "derived": {"dual": d6, "speedup": t0 / t6,
                             "speedup_vs_it4": t4 / t6,
                             "dual_drift_rel": abs(d6 - d0) / abs(d0),
                             "dual_drift_rel_vs_aligned":
                                 abs(d6 - d4) / abs(d4)}})
    # it7: x-carry through the gvals-free Pallas kernels
    t7, d7 = _time_solve(lp, "boxcut", 20, ax_mode="aligned",
                         use_pallas=True, iterations=iters, repeats=reps)
    rows.append({"name": "perf_lp/it7_xcarry_pallas",
                 "us_per_call": t7 * 1e6,
                 "derived": {"dual": d7, "speedup": t0 / t7,
                             "speedup_vs_it5": t5 / t7,
                             "dual_drift_rel": abs(d7 - d0) / abs(d0),
                             "dual_drift_rel_vs_aligned":
                                 abs(d7 - d5) / abs(d5)}})
    return _stamp_resources(rows)


def run_bytes(quick: bool = False):
    """Analytic bytes-per-iteration of the three Ax lowerings (module doc).

    Lowers `MatchingObjective.calculate` for scatter / aligned_gvals /
    aligned (x-carry) on a multi-family Appendix-B instance and walks the
    compiled HLO with launch/hlo_cost.py.  Reported per lowering:
      bytes        total operand+result HBM bytes (hlo_cost convention)
      dyn_bytes    the same excluding static parameter/constant reads
      edge_bytes   dynamic edge-space materializations (leading dim == E)
      gvals_em     number of (E, m)-shaped tensors anywhere in the module
    The acceptance claims ride on the aligned rows: gvals_em == 0 for
    x-carry, and edge-space dynamic traffic reduced >= 2x (== m, here 4x,
    up to XLA copy elision) vs the gvals-based aligned lowering."""
    import jax
    import jax.numpy as jnp
    from repro.core import InstanceSpec, generate
    from repro.launch import hlo_cost

    I = 2_000 if quick else 10_000
    spec = InstanceSpec(num_sources=I, num_destinations=100,
                        avg_nnz_per_row=max(0.001 * I, 4.0), seed=42,
                        num_families=4)
    lp = jax.tree.map(jnp.asarray, generate(spec))
    lp, _ = precondition(lp, row_norm=True)
    E = sum(s.n * s.width for s in lp.slabs)
    m = lp.m
    lam = jnp.zeros((m, lp.num_destinations), jnp.float32)
    gamma = jnp.float32(0.01)
    stats = {}
    for mode in ("scatter", "aligned_gvals", "aligned"):
        obj = MatchingObjective(lp, proj_kind="boxcut", proj_iters=20,
                                ax_mode=mode)
        txt = jax.jit(obj.calculate).lower(lam, gamma).compile().as_text()
        stats[mode] = {
            "bytes": hlo_cost.analyze(txt)["bytes_per_device"],
            "dyn_bytes": hlo_cost.analyze(
                txt, dynamic_only=True)["bytes_per_device"],
            "edge_bytes": hlo_cost.edge_space_result_bytes(txt, E),
            "gvals_em": hlo_cost.count_result_shape(txt, (E, m)),
        }
    gv, xc = stats["aligned_gvals"], stats["aligned"]
    # XLA may elide the x concat entirely (edge_bytes == 0); floor the
    # denominator at one (E,) f32 write so the ratio stays meaningful
    ratio = gv["edge_bytes"] / max(xc["edge_bytes"], 4.0 * E)
    derived = {"instance": f"I{I}_J100_m{m}", "num_edges_padded": int(E)}
    for mode, s in stats.items():
        derived.update({f"{k}_{mode}": v for k, v in s.items()})
    derived["edge_traffic_ratio_gvals_over_xcarry"] = ratio
    derived["xcarry_materializes_gvals"] = bool(xc["gvals_em"])
    return _stamp_resources(
        [{"name": "perf_lp/bytes_per_iteration", "us_per_call": 0.0,
          "derived": derived}])


def run_tolerance(quick: bool = False):
    """Wall-clock-to-tolerance — the paper's actual headline metric.

    The ≥10x claim is made "under matched stopping criteria": both Ax
    layouts run under ONE StoppingCriteria (same tolerances, same check
    cadence) and each row reports the seconds and iterations it took to get
    there, plus the stop reason.  The scatter row is wall-clock-capped: on
    this CPU host it may exhaust the budget before reaching tolerance, and
    `stop_reason="max_seconds"` records that honestly instead of a
    fixed-iteration timing pretending both did equal work.  Sizes are scaled
    down from the fixed-iteration rows so the converging row finishes in
    minutes on one core."""
    I = 2_000 if quick else 10_000
    spec, lp_host = bench_instance(I)
    lp = jax.tree.map(jnp.asarray, lp_host)
    lp, _ = precondition(lp, row_norm=True)
    cfg = SolveConfig(iterations=4000, gamma=0.01, max_step=1e-1,
                      initial_step=1e-5)
    crit = StoppingCriteria(tol_rel_dual=1e-6, tol_infeas_rel=1e-4,
                            check_every=25,
                            max_seconds=60.0 if quick else 300.0)
    rows, secs = [], {}
    by_name = {}
    for tag, ax_mode in [("scatter", "scatter"),
                         ("aligned", "aligned_gvals"),
                         ("xcarry", "aligned")]:
        obj = MatchingObjective(lp, proj_kind="boxcut", proj_iters=20,
                                ax_mode=ax_mode)
        mx = Maximizer(cfg)
        # warm-up: compile the check_every-length chunk runner (same engine
        # cache key as the timed run) so the row times iterations to
        # tolerance, not each layout's XLA compile
        warm = mx.maximize(obj, criteria=StoppingCriteria(
            max_iterations=crit.check_every))
        jax.block_until_ready(warm.lam)
        # best-of-3: this host's effective CPU speed drifts ~2x over
        # minutes, so a single timed solve can misattribute a slow window
        # to a layout; the trajectory is deterministic, only dt varies
        dt = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            res = mx.maximize(obj, criteria=crit)
            jax.block_until_ready(res.lam)
            dt = min(dt, time.perf_counter() - t0)
        secs[tag] = (dt, res)
        row = {
            "name": f"perf_lp/tol_{tag}",
            "us_per_call": dt / max(res.iterations_run, 1) * 1e6,
            "derived": {
                "seconds_to_stop": dt,
                "iterations_run": res.iterations_run,
                "stop_reason": res.stop_reason.value,
                "converged": res.converged,
                "dual": float(res.stats.dual_obj[-1]),
                "infeas": float(res.stats.infeas[-1]),
                "checks": len(res.diagnostics),
            }}
        rows.append(row)
        by_name[tag] = row
    dt_sc, res_sc = secs["scatter"]
    dt_al, res_al = secs["aligned"]
    dt_xc, res_xc = secs["xcarry"]
    d_al = by_name["aligned"]["derived"]
    d_al["wallclock_speedup_vs_scatter"] = dt_sc / dt_al
    if res_sc.converged and res_al.converged:
        d_al["dual_drift_rel"] = (
            abs(float(res_al.stats.dual_obj[-1])
                - float(res_sc.stats.dual_obj[-1]))
            / abs(float(res_sc.stats.dual_obj[-1])))
    # the x-carry acceptance pair: same matched criteria as the gvals-
    # aligned row; its drift vs that row is the CI convergence gate, and
    # wall-clock-to-tolerance must not regress (it does strictly less work)
    d_xc = by_name["xcarry"]["derived"]
    d_xc["wallclock_speedup_vs_scatter"] = dt_sc / dt_xc
    d_xc["wallclock_speedup_vs_aligned"] = dt_al / dt_xc
    if res_al.converged and res_xc.converged:
        d_xc["dual_drift_rel_vs_aligned"] = (
            abs(float(res_xc.stats.dual_obj[-1])
                - float(res_al.stats.dual_obj[-1]))
            / abs(float(res_al.stats.dual_obj[-1])))

    # run-log citation (DESIGN.md §11): one extra instrumented x-carry
    # solve AFTER the timed best-of-3 (telemetry off during timing) writes
    # a full JSONL run log next to the other artifacts; the row cites its
    # path and the compile/execute/host span totals so the headline number
    # is accompanied by where the milliseconds went
    # (`python -m repro.launch.report` renders the rest).
    from repro.obs import Telemetry
    from repro.launch import report as runlog_report
    log_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "results", "runlogs", "tol_xcarry.jsonl")
    os.makedirs(os.path.dirname(log_path), exist_ok=True)
    if os.path.exists(log_path):
        os.remove(log_path)
    tel = Telemetry.jsonl(log_path, level="error")
    try:
        tel.manifest(suite="perf_lp/tol_xcarry", instance_sources=I,
                     algorithm="agd", formulation="matching")
        obj = MatchingObjective(lp, proj_kind="boxcut", proj_iters=20,
                                ax_mode="aligned")
        Maximizer(cfg).maximize(obj, criteria=crit, telemetry=tel)
    finally:
        tel.close()
    summary = runlog_report.summarize(runlog_report.load_run(log_path))
    d_xc["run_log"] = os.path.relpath(
        log_path, os.path.dirname(os.path.dirname(log_path)))
    d_xc["span_totals_s"] = summary["span_totals"]

    # the formulation-subsystem row: multi_budget (capacity + global count
    # + global value caps, DESIGN.md §5) compiled onto the same engine with
    # the aligned layout — keeps the new subsystem on the perf trajectory.
    # Stopping: the dual-stability rule at the same tolerance/cadence; the
    # infeasibility rule is dropped for this row because its binding
    # coupling rows carry a γ-regularization residual floor (reported in
    # `infeas`) that no fixed tol_infeas_rel can undercut across instances.
    from repro import formulations
    crit_mb = StoppingCriteria(tol_rel_dual=crit.tol_rel_dual,
                               check_every=crit.check_every,
                               max_seconds=crit.max_seconds)
    obj = formulations.make_objective("multi_budget", lp_host,
                                      ax_mode="aligned", row_norm=True)
    mx = Maximizer(cfg)
    warm = mx.maximize(obj, criteria=StoppingCriteria(
        max_iterations=crit.check_every))
    jax.block_until_ready(warm.lam)
    dt = float("inf")
    for _ in range(3):   # best-of-3, same rationale as the rows above
        t0 = time.perf_counter()
        res = mx.maximize(obj, criteria=crit_mb)
        jax.block_until_ready(res.lam)
        dt = min(dt, time.perf_counter() - t0)
    rows.append({
        "name": "perf_lp/tol_multi_budget_aligned",
        "us_per_call": dt / max(res.iterations_run, 1) * 1e6,
        "derived": {
            "seconds_to_stop": dt,
            "iterations_run": res.iterations_run,
            "stop_reason": res.stop_reason.value,
            "converged": res.converged,
            "dual": float(res.stats.dual_obj[-1]),
            "infeas": float(res.stats.infeas[-1]),
            "checks": len(res.diagnostics),
            "dual_rows": int(obj.dual_shape[0]),
        }})

    # --- per-update-rule rows (DESIGN.md §10): agd vs pdhg vs bb ---------
    # Every registered formulation × every competitive rule, under ONE
    # shared StoppingCriteria (dual stability AND feasibility — a solver
    # race decided by dual stagnation alone rewards the rule that stalls
    # first, so the uniform criterion requires both).  The x-carry aligned
    # lowering for all rows; warm-up per combo excludes compile; iteration
    # counts are deterministic, wall-clock is informational (single timed
    # run — this host's clock drifts, and the gate rides on iterations).
    # Headline rows (matching): tol_agd / tol_pdhg / tol_bb; other
    # formulations get tol_<rule>_<formulation>.  The pdhg rows carry
    # iters_speedup_vs_agd — the acceptance claim is >= 2x on at least two
    # formulations (tol_rules_summary.pdhg_2x_count) — and every rule row
    # carries dual_drift_rel_vs_agd as the same-answer guard.
    crit_rules = StoppingCriteria(tol_rel_dual=1e-6, tol_infeas_rel=1e-4,
                                  check_every=25,
                                  max_seconds=120.0 if quick else 600.0)
    cfg_rules = SolveConfig(iterations=30000, gamma=0.01, max_step=1e-1,
                            initial_step=1e-5)
    forms = ("matching", "global_count", "multi_budget", "assignment_eq")
    rules = ("agd", "pdhg", "bb")
    agd_res = {}
    for rule in rules:
        for form in forms:
            params = {"proj_iters": 20} if form == "matching" else None
            obj = formulations.make_objective(form, lp_host, params=params,
                                              ax_mode="aligned",
                                              row_norm=True)
            mx = Maximizer(cfg_rules, algorithm=rule)
            warm = mx.maximize(obj, criteria=StoppingCriteria(
                max_iterations=crit_rules.check_every))
            jax.block_until_ready(warm.lam)
            t0 = time.perf_counter()
            res = mx.maximize(obj, criteria=crit_rules)
            jax.block_until_ready(res.lam)
            dt = time.perf_counter() - t0
            name = (f"perf_lp/tol_{rule}" if form == "matching"
                    else f"perf_lp/tol_{rule}_{form}")
            derived = {
                "algorithm": rule,
                "formulation": form,
                "seconds_to_stop": dt,
                "iterations_run": res.iterations_run,
                "stop_reason": res.stop_reason.value,
                "converged": res.converged,
                "dual": float(res.stats.dual_obj[-1]),
                "infeas": float(res.stats.infeas[-1]),
                "checks": len(res.diagnostics),
            }
            if rule == "agd":
                agd_res[form] = derived
            else:
                base = agd_res[form]
                derived["iters_speedup_vs_agd"] = (
                    base["iterations_run"] / max(res.iterations_run, 1))
                derived["wallclock_speedup_vs_agd"] = (
                    base["seconds_to_stop"] / max(dt, 1e-9))
                derived["dual_drift_rel_vs_agd"] = (
                    abs(derived["dual"] - base["dual"]) / abs(base["dual"]))
            rows.append({"name": name,
                         "us_per_call": dt / max(res.iterations_run, 1) * 1e6,
                         "derived": derived})
    by = {r["name"]: r["derived"] for r in rows}
    pdhg_speedups = {
        form: by[f"perf_lp/tol_pdhg" if form == "matching"
                 else f"perf_lp/tol_pdhg_{form}"].get(
                     "iters_speedup_vs_agd", 0.0)
        for form in forms}
    rows.append({
        "name": "perf_lp/tol_rules_summary", "us_per_call": 0.0,
        "derived": {
            "formulations": list(forms),
            "pdhg_iters_speedup": pdhg_speedups,
            "pdhg_2x_count": sum(1 for v in pdhg_speedups.values()
                                 if v >= 2.0),
        }})
    return _stamp_resources(rows)


def run_serve(quick: bool = False):
    """Primal serving: extraction throughput + microbatch query latency
    (module doc).  One solved instance; both measurements exclude compile
    via a warm-up pass, matching the suite's timing protocol."""
    import numpy as np
    from repro import primal as primal_sub

    I = 2_000 if quick else 10_000
    spec, lp_host = bench_instance(I)
    lp = jax.tree.map(jnp.asarray, lp_host)
    lp, _ = precondition(lp, row_norm=True)
    cfg = SolveConfig(iterations=4000, gamma=0.01, max_step=1e-1,
                      initial_step=1e-5)
    crit = StoppingCriteria(tol_rel_dual=1e-6, check_every=25,
                            max_seconds=60.0 if quick else 300.0)
    obj = MatchingObjective(lp, proj_kind="boxcut", proj_iters=20,
                            ax_mode="aligned")
    res = Maximizer(cfg).maximize(obj, criteria=crit)
    jax.block_until_ready(res.lam)
    gamma = jnp.float32(cfg.gamma)
    chunk = 1024

    # extraction throughput: warm-up compiles the per-(slab, chunk) row
    # kernels, then one timed full pass
    n_src = sum(s.n for s in lp.slabs)
    primal_sub.extract_primal(obj, res.lam, gamma, chunk_rows=chunk)
    t0 = time.perf_counter()
    xs = primal_sub.extract_primal(obj, res.lam, gamma, chunk_rows=chunk)
    dt_extract = time.perf_counter() - t0

    # microbatch query latency through the λ-resident server
    srv = primal_sub.AllocationServer(obj, res.lam, gamma, max_batch=64)
    all_ids = srv.source_ids()
    batch = 32
    rng = np.random.default_rng(0)
    kernels = srv.warmup()      # compile every (slab, pad-length) kernel
    srv.reset_stats()
    n_queries = 30 if quick else 100
    for _ in range(n_queries):
        srv.query(rng.choice(all_ids, size=batch, replace=False).tolist())
    st = srv.stats()

    cert = primal_sub.certify(obj, res.lam, gamma, xs=primal_sub.repair_witness(obj, xs))
    return _stamp_resources([{
        "name": "perf_lp/serve",
        "us_per_call": st.mean_ms * 1e3,
        "derived": {
            "instance": f"I{I}_J1000",
            "solve_iterations": res.iterations_run,
            "solve_converged": res.converged,
            "extract_seconds": dt_extract,
            "extract_sources_per_s": n_src / max(dt_extract, 1e-9),
            "chunk_rows": chunk,
            "query_batch": batch,
            "query_p50_ms": st.p50_ms,
            "query_p95_ms": st.p95_ms,
            "query_sources_per_s": st.sources_per_s,
            "queries": st.queries,
            "warmup_kernels": kernels,
            "certificate_gap_rel": cert.gap_rel,
            "certificate_feasible": cert.feasible,
            "certificate_valid": cert.valid,
        }}])


def run_load(quick: bool = False):
    """Closed-loop load test through the ServerFrontend (module doc)."""
    import threading

    import numpy as np
    from repro import primal as primal_sub
    from repro.primal import FrontendConfig, RequestStatus, ServerFrontend

    I = 2_000 if quick else 10_000
    clients = 4
    phase_s = 2.0 if quick else 6.0
    spec, lp_host = bench_instance(I)
    lp = jax.tree.map(jnp.asarray, lp_host)
    lp, _ = precondition(lp, row_norm=True)
    cfg = SolveConfig(iterations=4000, gamma=0.01, max_step=1e-1,
                      initial_step=1e-5)
    crit = StoppingCriteria(tol_rel_dual=1e-6, check_every=25,
                            max_seconds=60.0 if quick else 300.0)
    obj = MatchingObjective(lp, proj_kind="boxcut", proj_iters=20,
                            ax_mode="aligned")
    res = Maximizer(cfg).maximize(obj, criteria=crit)
    jax.block_until_ready(res.lam)
    gamma = jnp.float32(cfg.gamma)
    srv = primal_sub.AllocationServer(obj, res.lam, gamma, config=cfg,
                                      max_batch=64)
    srv.warmup()
    ids_pool = srv.source_ids()
    batch = 8
    rng = np.random.default_rng(0)
    per_query = None
    t0 = time.perf_counter()
    for _ in range(30):   # raw device round trip, for the deadline scale
        srv.query(rng.choice(ids_pool, size=batch, replace=False).tolist())
    per_query = (time.perf_counter() - t0) / 30
    fe_cfg = FrontendConfig(max_queue=64, max_batch=64)
    deadline = max(30.0 * per_query + fe_cfg.max_wait_s, 0.05)

    def drive(n_clients, frontend, mid_run=None):
        results = [[] for _ in range(n_clients)]
        failures = []

        def client(k):
            rng_k = np.random.default_rng(100 + k)
            end = time.monotonic() + phase_s
            try:
                while time.monotonic() < end:
                    ids = rng_k.choice(ids_pool, size=batch,
                                       replace=False).tolist()
                    results[k].append(frontend.query(
                        ids, deadline_s=deadline, timeout=120.0))
            except Exception as e:
                failures.append(repr(e))

        threads = [threading.Thread(target=client, args=(k,))
                   for k in range(n_clients)]
        t_run = time.perf_counter()
        for t in threads:
            t.start()
        if mid_run is not None:
            time.sleep(phase_s / 3.0)
            mid_run()
        for t in threads:
            t.join(timeout=phase_s + 300.0)
        wall = time.perf_counter() - t_run
        if failures or any(t.is_alive() for t in threads):
            raise RuntimeError(f"load-test client failed: {failures}")
        return [r for rs in results for r in rs], wall

    # phase 1: single-client sustained rate through the same frontend path
    fe1 = ServerFrontend(srv, fe_cfg)
    flat1, wall1 = drive(1, fe1)
    fe1.drain()
    qps_single = len(flat1) / wall1

    # phase 2: concurrency + a warm re-solve landing mid-run
    fe = ServerFrontend(srv, fe_cfg)
    refresh_launched = []
    flat, wall = drive(
        clients, fe,
        mid_run=lambda: refresh_launched.append(
            fe.refresh(criteria=crit, force=True)))
    refresh_status, res_w = fe.wait_refresh(timeout=600.0)
    snap = fe.drain()

    errors = [r for r in flat if r.status is RequestStatus.ERROR]
    if errors:
        raise RuntimeError(
            f"{len(errors)} unclassified failures under load "
            f"(first: {errors[0].reason!r})")
    classified = (snap["ok_total"] + snap["shed_total"]
                  + snap["timeout_total"] + snap["error_total"])
    if classified != snap["submitted_total"]:
        raise RuntimeError("drain left unanswered requests")
    ok = [r for r in flat if r.status is RequestStatus.OK]
    if not ok:
        raise RuntimeError("no request completed OK under load")
    lat = np.asarray([r.latency_s for r in ok])
    qps = len(flat) / wall
    return _stamp_resources([{
        "name": "perf_lp/serve_load",
        "us_per_call": float(lat.mean() * 1e6) if lat.size else 0.0,
        "derived": {
            "instance": f"I{I}_J1000",
            "clients": clients,
            "phase_seconds": phase_s,
            "deadline_ms": deadline * 1e3,
            "qps_single_client": qps_single,
            "qps_concurrent": qps,
            "concurrency_speedup": qps / max(qps_single, 1e-9),
            "requests": len(flat),
            "ok": len(ok),
            "shed": int(snap["shed_total"]),
            "timeout": int(snap["timeout_total"]),
            "errors": 0,
            "shed_rate": snap["shed_total"] / max(len(flat), 1),
            "ok_p50_ms": float(np.percentile(lat, 50) * 1e3),
            "ok_p99_ms": float(np.percentile(lat, 99) * 1e3),
            "p99_within_deadline": bool(
                np.percentile(lat, 99) <= deadline + 0.005),
            "batches": int(snap["batches_total"]),
            "refresh_launched": bool(refresh_launched
                                     and refresh_launched[0]),
            "refresh_status": refresh_status,
            "refresh_converged": bool(res_w is not None
                                      and res_w.converged),
        }}])
