"""§Perf hillclimb, cell 3: the LP solver iteration (the paper's technique).

Unlike the LM cells (analyzed via compiled rooflines), the solver runs for
real on this host, so each hypothesis is validated by measured wall-clock
per-iteration time at I=100k × J=1k (and by checking the converged dual is
unchanged).  Iterations:

  it0  baseline: paper-faithful pipeline (bucketed slabs, 40-sweep bisection
       projection, segment-sum gradient), jit-compiled.
  it1  hypothesis: the projection's 40 masked clip+sum sweeps dominate the
       per-iteration time (napkin: 40 sweeps x nnz ops vs ~6 sweeps for
       everything else). change: bisection 40 -> 20 sweeps (τ precision
       2^-20·range ≈ f32 noise here).  expect ~linear cut of projection time.
  it2  hypothesis: a safeguarded-Newton threshold search needs ~1/3 the
       sweeps of pure bisection on piecewise-linear f. change: kind
       "boxcut_newton" (12 sweeps, bracket-safeguarded).
  it3  hypothesis: two passes over a_vals (u = −(Aᵀλ+c)/γ, then gvals=a·x)
       dominate memory traffic after it2; fusing them is what the Pallas
       dual_grad kernel does on TPU — on CPU XLA already fuses, so expect
       ~no change (refutation expected; documents why the kernel targets
       TPU VMEM, not CPU cache).  change: use_pallas=False vs the fused
       jnp expression ordering.

Each row reports: us/iter, speedup vs baseline, and |Δdual| of the converged
objective vs baseline (must be ~0 for accepted changes).
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (MatchingObjective, Maximizer, SolveConfig,
                        precondition)
from .lp_common import bench_instance


def _time_solve(lp, kind: str, proj_iters: int, iterations: int = 60,
                repeats: int = 3, sorted_scatter: bool = False):
    cfg = SolveConfig(iterations=iterations, gamma=0.01, max_step=1e-3,
                      initial_step=1e-5)
    obj = MatchingObjective(lp, proj_kind=kind, proj_iters=proj_iters,
                            sorted_scatter=sorted_scatter)
    mx = Maximizer(cfg)
    res = mx.maximize(obj)
    jax.block_until_ready(res.lam)
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = mx.maximize(obj)
        jax.block_until_ready(res.lam)
        best = min(best, (time.perf_counter() - t0) / iterations)
    return best, float(res.stats.dual_obj[-1])


def run(quick: bool = False):
    I = 50_000 if quick else 100_000
    spec, lp_host = bench_instance(I)
    lp = jax.tree.map(jnp.asarray, lp_host)
    lp, _ = precondition(lp, row_norm=True)

    rows = []
    t0, d0 = _time_solve(lp, "boxcut", 40)
    rows.append({"name": "perf_lp/it0_baseline_bisect40",
                 "us_per_call": t0 * 1e6,
                 "derived": {"dual": d0, "speedup": 1.0}})
    t1, d1 = _time_solve(lp, "boxcut", 20)
    rows.append({"name": "perf_lp/it1_bisect20",
                 "us_per_call": t1 * 1e6,
                 "derived": {"dual": d1, "speedup": t0 / t1,
                             "dual_drift_rel": abs(d1 - d0) / abs(d0)}})
    t2, d2 = _time_solve(lp, "boxcut_newton", 12)
    rows.append({"name": "perf_lp/it2_newton12",
                 "us_per_call": t2 * 1e6,
                 "derived": {"dual": d2, "speedup": t0 / t2,
                             "dual_drift_rel": abs(d2 - d0) / abs(d0)}})
    # it3: sorted-destination segmented sum replaces the random scatter-add
    # (keeps it1's accepted bisect20)
    t3, d3 = _time_solve(lp, "boxcut", 20, sorted_scatter=True)
    rows.append({"name": "perf_lp/it3_bisect20_sorted_scatter",
                 "us_per_call": t3 * 1e6,
                 "derived": {"dual": d3, "speedup": t0 / t3,
                             "dual_drift_rel": abs(d3 - d0) / abs(d0)}})
    return rows
