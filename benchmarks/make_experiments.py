"""Render the data-driven sections of EXPERIMENTS.md from dry-run JSONs +
bench results.  Usage:
    PYTHONPATH=src python -m benchmarks.make_experiments > /tmp/sections.md
The hand-written narrative (§Perf iteration log etc.) lives in
EXPERIMENTS.md directly; this tool regenerates the tables between the
AUTOGEN markers.
"""
from __future__ import annotations

import glob
import json
import os

from . import roofline_report

RESULTS = roofline_report.RESULTS


def dryrun_section() -> str:
    out = ["## §Dry-run", ""]
    for mesh, label in (("single", "single-pod 16x16 (256 chips)"),
                        ("multipod", "multi-pod 2x16x16 (512 chips)")):
        d = os.path.join(RESULTS, mesh)
        if not os.path.isdir(d):
            continue
        cells = roofline_report.load_cells(mesh)
        n_ok = sum(1 for c in cells if c["status"] == "OK")
        n_skip = sum(1 for c in cells if c["status"] == "SKIP")
        n_fail = sum(1 for c in cells if c["status"] == "FAIL")
        out.append(f"### {label}: {n_ok} OK, {n_skip} SKIP (documented), "
                   f"{n_fail} FAIL")
        out.append("")
        out.append("| cell | kind | compile (s) | HBM/dev (GB) | "
                   "HLO GFLOPs/dev | HLO GB/dev | coll MB/dev | #coll |")
        out.append("|---|---|---|---|---|---|---|---|")
        for c in cells:
            name = f"{c.get('arch')}/{c.get('shape')}"
            if c["status"] == "SKIP":
                out.append(f"| {name} | — | — | — | — | — | — | SKIP |")
                continue
            if c["status"] == "FAIL":
                out.append(f"| {name} | — | — | — | — | — | — | **FAIL** |")
                continue
            coll_dev = (c["roofline"]["collective_bytes_global"]
                        / c["n_devices"] / 1e6)
            out.append(
                f"| {name} | {c.get('kind','')} | {c['compile_s']:.0f} | "
                f"{c['hbm_per_device_gb']:.2f} | "
                f"{c['cost']['flops_per_device']/1e9:.1f} | "
                f"{c['cost']['bytes_per_device']/1e9:.2f} | "
                f"{coll_dev:.1f} | {c['collectives'].get('count', 0)} |")
        out.append("")
    return "\n".join(out)


def roofline_section() -> str:
    out = ["## §Roofline", ""]
    out.append("Terms per assignment (TPU v5e: 197 TF/s bf16, 819 GB/s HBM, "
               "50 GB/s/link ICI); HLO flops/bytes from the trip-count-aware "
               "walker (launch/hlo_cost.py), MODEL_FLOPS = 6·N_active·D "
               "(2·N_active·D for inference).")
    out.append("")
    for mesh in ("single", "multipod"):
        if not os.path.isdir(os.path.join(RESULTS, mesh)):
            continue
        out.append(f"### mesh: {mesh}")
        out.append("")
        out.append(roofline_report.markdown_table(mesh))
        out.append("")
    return "\n".join(out)


def main():
    print(dryrun_section())
    print()
    print(roofline_section())


if __name__ == "__main__":
    main()
